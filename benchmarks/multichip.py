"""Benchmark: multi-chip placement on a HierarchicalMesh (2×2 chips of 4×4).

The topology refactor's headline workload: a 64-core system built from four
4×4 mesh chips joined by 8× slower, 8× costlier inter-chip links
(`repro.core.topology.HierarchicalMesh`). Sweeps the placement methods —
the flat constructors (zigzag, sigmate), random search, simulated annealing,
PPO, and the new `genetic` evolutionary search — the searches at a matched
evaluation budget (PPO runs its paper-style config instead: batch_size ×
iterations rollouts, fewer evaluations but far more wall time), under the
comm-cost objective plus a chip-aware `{comm_cost, interchip}` combo for the
genetic method, recording for each:

* ``comm_cost``       — Σ bytes × hops on the global grid;
* ``interchip_bytes`` — bytes crossing inter-chip links (the quantity the
  slow links make expensive);
* ``energy``          — per-link-energy-aware J/step;
* ``latency``/``max_link`` and wall time.

Acceptance (ISSUE 4): genetic beats random search on comm_cost while crossing
fewer inter-chip bytes than the best flat-aware baseline (zigzag / sigmate /
random search). The emitted ``results/BENCH_multichip.json`` carries an
``acceptance`` block asserting both. ``--smoke`` runs a seconds-scale subset
(tiny chips/budgets, no JSON) for CI.
"""
from __future__ import annotations

import argparse
import os

from .common import (counter_record, model_graph,  # also sets up sys.path
                     write_record, write_trace)
from repro.core import HierarchicalMesh
from repro.core.placement import optimize_placement
from repro.core.placement.ppo import PPOConfig
from repro.deploy.objective import as_objective
from repro.obs import Recorder

FLAT_BASELINES = ("zigzag", "sigmate", "random_search")


def _case(graph, hm, method, budget, objective="comm_cost", recorder=None,
          **kw):
    res = optimize_placement(graph, hm, method=method, budget=budget,
                             seed=0, objective=objective, recorder=recorder,
                             **kw)
    m = hm.evaluate(graph, res.placement)
    energy = as_objective("energy").from_metrics(m, hm)
    return {
        "method": method,
        "objective": res.objective,
        "comm_cost": float(res.comm_cost),
        "interchip_bytes": float(hm.interchip_bytes(m.link_traffic)),
        "energy_j": float(energy),
        "max_link": float(res.max_link),
        "latency_s": float(res.latency),
        "wall_time_s": float(res.wall_time_s),
    }


def multichip(smoke: bool = False, json_path: str | None = None):
    if smoke:
        hm = HierarchicalMesh(2, 2, 2, 2, link_bw=8e9, core_flops=25.6e9,
                              hop_latency=2e-8)
        model, budget, ppo_cfg = "S-ResNet18", 240, PPOConfig(
            batch_size=16, iterations=4, ppo_epochs=2, seed=0)
        pop = 16
    else:
        hm = HierarchicalMesh(2, 2, 4, 4, link_bw=8e9, core_flops=25.6e9,
                              hop_latency=2e-8)
        model, budget, ppo_cfg = "S-VGG16", 4096, PPOConfig(
            batch_size=64, iterations=30, ppo_epochs=4, entropy_coef=3e-3,
            seed=0)
        pop = 64
    graph, _ = model_graph(model, hm.n_cores)

    recorder = Recorder()       # whole-sweep trace + deterministic counters
    cases = []
    for method, kw in [("zigzag", {}), ("sigmate", {}),
                       ("random_search", {}),
                       ("simulated_annealing", {}),
                       ("genetic", {"pop_size": pop}),
                       ("ppo", {"cfg": ppo_cfg})]:
        cases.append(_case(graph, hm, method, budget, recorder=recorder,
                           **kw))
    # chip-aware genetic: penalize boundary crossings directly
    ic_w = 2.0
    chip_aware = _case(graph, hm, "genetic", budget,
                       objective={"comm_cost": 1.0, "interchip": ic_w},
                       pop_size=pop, recorder=recorder)
    cases.append(chip_aware)

    by = {c["method"]: c for c in cases if c["objective"] == "comm_cost"}
    best_flat_ic = min(by[m]["interchip_bytes"] for m in FLAT_BASELINES)
    acceptance = {
        "genetic_beats_random_search_comm_cost":
            by["genetic"]["comm_cost"] < by["random_search"]["comm_cost"],
        "genetic_interchip_below_best_flat_baseline":
            by["genetic"]["interchip_bytes"] < best_flat_ic,
        "best_flat_baseline_interchip_bytes": best_flat_ic,
    }

    record = {
        "smoke": smoke,
        "topology": hm.describe(),
        "model": model,
        "budget": budget,
        "cases": cases,
        "acceptance": acceptance,
        "counters": counter_record(recorder),
    }
    rows = []
    for c in cases:
        tag = "genetic+ic" if "interchip" in c["objective"] else c["method"]
        rows.append((
            f"multichip.{tag}", c["wall_time_s"] * 1e6,
            f"comm={c['comm_cost']:.3e} interchip={c['interchip_bytes']:.3e} "
            f"energy={c['energy_j']:.3e} max_link={c['max_link']:.3e}"))
    if not smoke:
        # the acceptance claims are about the full-size run; at smoke scale
        # the seeded constructors can already be optimal and genetic merely
        # ties them
        ok_rs = acceptance["genetic_beats_random_search_comm_cost"]
        ok_ic = acceptance["genetic_interchip_below_best_flat_baseline"]
        rows.append(("multichip.acceptance", 0.0,
                     f"genetic<rs_comm={ok_rs} genetic<flat_interchip={ok_ic}"))
    out = write_record(record, json_path, smoke, "BENCH_multichip.json")
    if out:
        rows.append(("multichip.json", 0.0, f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "multichip", json_path, smoke)
    if tr:
        rows.append(("multichip.trace", 0.0, f"wrote {os.path.relpath(tr)}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI subset (tiny chips/budgets)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in multichip(smoke=args.smoke,
                                       json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
