"""One benchmark function per paper table/figure (Fig 4/6/7-11, Table 1).

Each returns a list of CSV rows (name, us_per_call, derived-metric string).
All numbers come from the same simulator stack the paper used (NoC + partition
+ pipeline models) — reproduction targets noted inline.
"""
from __future__ import annotations


from .common import (CORE_FLOPS, SPIKE_MODELS, make_noc, model_graph,
                     placement_suite, timed)
from repro.core import partition_model, pipeline
from repro.core.placement.policy_baseline import PolicyConfig, run_policy_baseline
from repro.snn import profile_model, spike_resnet18


# ---------------------------------------------------------------- Table 1 ----

def table1_eer():
    """SNN inference EER: many-core near-memory vs GPU-like device (modeled).

    Paper Table 1: HP300 reaches ~18x (Unet) / ~10x (ResNet50) the EER of a
    V100. We model: GPU = monolithic device, 60% idle power overhead, batch-1
    latency dominated by kernel-launch-like fixed cost; many-core = fpdeep
    pipeline over a 32-core partition with near-memory power/core.
    """
    from repro.core import partition_model
    from repro.snn import profile_model as _pm, spike_resnet18 as _r18, \
        spike_resnet50 as _r50
    rows = []
    for name, builder in (("S-ResNet18", _r18), ("S-ResNet50", _r50)):
        cfg = builder(n_classes=1000, in_res=224, T=4)   # ImageNet-scale
        part = partition_model(_pm(cfg, batch=1, training=False), 32,
                               "balanced")
        times = [s.flops / CORE_FLOPS for s in part.slices]
        (sch, us) = timed(pipeline.fpdeep, times, 8, training=False)
        fps_mc = 8 / sch.makespan
        p_core, p_base = 0.45, 1.5               # W per active core / chip base
        watts_mc = p_base + 32 * p_core * sch.mean_utilization()
        eer_mc = fps_mc / watts_mc
        total_flops = sum(s.flops for s in part.slices)
        gpu_flops, gpu_watts, gpu_fixed = 14e12, 90.0, 6e-3
        fps_gpu = 1.0 / (total_flops / (gpu_flops * 0.05) + gpu_fixed)
        eer_gpu = fps_gpu / gpu_watts
        rows.append((f"table1.eer.{name}", us,
                     f"eer_manycore={eer_mc:.2f}fps/W eer_gpu={eer_gpu:.2f} "
                     f"ratio={eer_mc/eer_gpu:.1f}x (paper ~10-18x)"))
    return rows


# ------------------------------------------------------------------ Fig 4 ----

def fig4_partition():
    """Partition-strategy balance on ImageNet-scale Spike-ResNet18 (32 cores):
    compute-only vs storage-only vs the paper's combined balancing."""
    cfg = spike_resnet18(n_classes=1000, in_res=224, T=4)
    prof = profile_model(cfg, batch=8)
    rows = []
    for strategy in ("compute", "storage", "balanced"):
        (part, us) = timed(partition_model, prof, 32, strategy)
        lat = part.latencies()
        rows.append((f"fig4.partition.{strategy}", us,
                     f"max/mean={part.imbalance():.3f} "
                     f"max_ms={lat.max()*1e3:.2f} mean_ms={lat.mean()*1e3:.2f}"))
    return rows


# ------------------------------------------------------------- Fig 6 / 8 ----

def _placement_fig(n_cores: int, training: bool, ppo_iters: int):
    rows = []
    noc = make_noc(n_cores)
    mode = "train" if training else "infer"
    for name in SPIKE_MODELS:
        graph, _ = model_graph(name, n_cores, training=training)
        (suite, us) = timed(placement_suite, graph, noc,
                            ppo_iters=ppo_iters)
        zz = suite["zigzag"]
        for m, r in suite.items():
            red = 100.0 * (1 - r.comm_cost / zz.comm_cost)
            rows.append((
                f"fig{6 if n_cores==32 else 8}.{mode}.{name}.{m}", us,
                f"comm={r.comm_cost:.3e} red_vs_zigzag={red:.1f}% "
                f"hops={r.mean_hops:.2f} lat={r.latency*1e3:.3f}ms "
                f"thr={r.throughput:.1f}/s"))
    return rows


def fig6_placement_32():
    """32-core deployment: paper reports 18.9-50.7% comm-cost reduction vs the
    baselines and ~0.67 lower mean hops (train+infer)."""
    return (_placement_fig(32, training=False, ppo_iters=32)
            + _placement_fig(32, training=True, ppo_iters=32))


def fig8_placement_64():
    """64-core generalization: paper reports >22.64% comm reduction."""
    return _placement_fig(64, training=True, ppo_iters=26)


# ------------------------------------------------------------ Fig 7 / 11 ----

def hotspots():
    """Communication hotspot balance: max-core-traffic / mean-core-traffic
    (lower = flatter heat map, paper Fig 7/11)."""
    rows = []
    noc = make_noc(32)
    for name in SPIKE_MODELS:
        graph, _ = model_graph(name, 32)
        suite = placement_suite(graph, noc, methods=("zigzag", "ppo"),
                                ppo_iters=32)
        out = {}
        for m, r in suite.items():
            traffic = noc.evaluate(graph, r.placement).core_traffic
            nz = traffic[traffic > 0]
            out[m] = float(nz.max() / nz.mean()) if nz.size else 0.0
        rows.append((f"fig7_11.hotspot.{name}", 0.0,
                     f"zigzag_peak/mean={out['zigzag']:.2f} "
                     f"ppo_peak/mean={out['ppo']:.2f}"))
    return rows


# ------------------------------------------------------------------ Fig 9 ----

def fig9_pipeline():
    """Layer-wise vs FPDeep fine-grained pipelining (training round)."""
    graph, part = model_graph("S-ResNet18", 32)
    times = [s.latency(part.core) for s in part.slices]
    (lw, us1) = timed(pipeline.layerwise, times, 8)
    (fp, us2) = timed(pipeline.fpdeep, times, 8)
    speed = lw.makespan / fp.makespan
    return [
        ("fig9.layerwise", us1,
         f"makespan_ms={lw.makespan*1e3:.2f} util={lw.mean_utilization():.3f}"),
        ("fig9.fpdeep", us2,
         f"makespan_ms={fp.makespan*1e3:.2f} util={fp.mean_utilization():.3f} "
         f"speedup={speed:.2f}x"),
    ]


# ----------------------------------------------------------------- Fig 10 ----

def fig10_vs_policy():
    """Ours (PPO+GCN, continuous actions) vs the prior 'Policy' method vs
    Zigzag. Paper: 6.5-8.7% comm reduction vs Policy, 29-43% vs Zigzag."""
    rows = []
    noc = make_noc(32)
    for name in ("S-ResNet18", "S-VGG16"):
        for training in (False, True):
            mode = "train" if training else "infer"
            graph, _ = model_graph(name, 32, training=training)
            suite = placement_suite(graph, noc, methods=("zigzag", "ppo"),
                                    ppo_iters=32)
            (pol, us) = timed(run_policy_baseline, graph, noc,
                              PolicyConfig(batch_size=48, iterations=16))
            zz, ours = suite["zigzag"].comm_cost, suite["ppo"].comm_cost
            rows.append((
                f"fig10.{mode}.{name}", us,
                f"zigzag={zz:.3e} policy={pol['best_cost']:.3e} "
                f"ours={ours:.3e} ours_vs_policy="
                f"{100*(1-ours/max(pol['best_cost'],1e-12)):.1f}% "
                f"ours_vs_zigzag={100*(1-ours/zz):.1f}%"))
    return rows
