"""Benchmark-regression gate for CI (``python -m benchmarks.check_regression``).

Runs every benchmark suite's ``--smoke`` mode in-process, writes the fresh
records to ``--out-dir`` (uploaded as CI artifacts), and compares each
suite's *deterministic* headline metrics against the committed baselines
``results/BENCH_<suite>_smoke.json`` within a per-metric tolerance band.
Timings are never gated (CI runners are too noisy); what is gated is the
seeded search results, parity deviations, schedule makespans, and the
deterministic work counters from the suites' recorders (scorer dispatch /
evaluation counts — they count algorithmic work, not time) — the quantities
a code regression actually moves.

Exit status is non-zero if any metric leaves its band (or a suite crashes),
which fails the CI job. The bands are two-sided on purpose: an unexplained
*improvement* is also a drift worth looking at — if it is intentional,
regenerate the baselines with ``--update-baselines`` and commit them
alongside the change (the benchmark regression policy in the README).

Metric kinds:

* ``rtol``     — relative band around the committed baseline value;
* ``max_abs``  — absolute ceiling, no baseline needed (parity deviations);
* ``expect``   — exact expected value (parity booleans).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import traceback

from .common import RESULTS_DIR


@dataclasses.dataclass(frozen=True)
class Metric:
    path: str                    # dotted path into the record; ints index lists
    rtol: float | None = None
    max_abs: float | None = None
    expect: object = None
    optional: bool = False       # absent in the fresh record -> skipped

    def __post_init__(self):
        if sum(x is not None for x in (self.rtol, self.max_abs,
                                       self.expect)) != 1:
            raise ValueError(f"{self.path}: exactly one of rtol/max_abs/"
                             "expect must be set")


_MISSING = object()


def get_path(record, path: str):
    """Extract ``a.0.b`` style dotted paths (ints index into lists)."""
    cur = record
    for seg in path.split("."):
        if isinstance(cur, list):
            try:
                cur = cur[int(seg)]
            except (ValueError, IndexError):
                return _MISSING
        elif isinstance(cur, dict):
            if seg not in cur:
                return _MISSING
            cur = cur[seg]
        else:
            return _MISSING
    return cur


def check_metric(metric: Metric, fresh, baseline) -> dict:
    """One metric's verdict: {'path', 'status', 'fresh', 'baseline', 'detail'}
    with status in {'ok', 'fail', 'skip'}."""
    val = get_path(fresh, metric.path)
    out = {"path": metric.path, "fresh": None if val is _MISSING else val,
           "baseline": None, "detail": ""}
    if val is _MISSING:
        out["status"] = "skip" if metric.optional else "fail"
        out["detail"] = "metric missing from fresh record"
        return out
    if metric.expect is not None:
        ok = val == metric.expect
        out["status"] = "ok" if ok else "fail"
        out["detail"] = "" if ok else f"expected {metric.expect!r}"
        return out
    if metric.max_abs is not None:
        ok = abs(float(val)) <= metric.max_abs
        out["status"] = "ok" if ok else "fail"
        out["detail"] = "" if ok else f"|{val:.3e}| > {metric.max_abs:.1e}"
        return out
    base = get_path(baseline, metric.path) if baseline is not None else _MISSING
    if base is _MISSING:
        out["status"] = "fail"
        out["detail"] = "metric missing from committed baseline"
        return out
    out["baseline"] = base
    band = metric.rtol * max(abs(float(base)), 1e-30)
    ok = abs(float(val) - float(base)) <= band
    out["status"] = "ok" if ok else "fail"
    if not ok:
        out["detail"] = (f"{float(val):.6e} vs baseline {float(base):.6e} "
                         f"(rtol {metric.rtol:g})")
    return out


def compare_suite(metrics, fresh, baseline) -> list:
    """All verdicts for one suite (pure — unit-tested with injected
    regressions in tests/test_check_regression.py)."""
    return [check_metric(m, fresh, baseline) for m in metrics]


# Deterministic-metric tolerance: the seeded numpy searches reproduce to the
# last ulp on one machine; the loose 1e-6 band absorbs summation-order drift
# across numpy/python versions in the CI matrix. jax-backed results (PPO)
# get a wide sanity band instead — they vary across jaxlib builds.
DET = 1e-6
PPO_BAND = 0.35

SUITES = {
    "noc_eval": [
        Metric("parity.max_rel_diff_numpy", max_abs=1e-9),
        Metric("parity.max_rel_diff_jax", max_abs=1e-4, optional=True),
        # observability invariants: recorder on/off must not change seeded
        # results, and the attached run's work counters are deterministic
        Metric("recorder_overhead.results_identical", expect=True),
        Metric("counters.noc_batch_dispatches", rtol=DET),
        Metric("counters.noc_batch_evals", rtol=DET),
    ],
    "ppo_pipeline": [
        Metric("pallas.matches_numpy", expect=True),
    ],
    "deploy_e2e": [
        Metric("cases.0.placement.comm_cost", rtol=DET),       # zigzag
        Metric("cases.1.placement.comm_cost", rtol=DET),       # random_search
        Metric("objective_demo.comm_cost.comm_cost", rtol=DET),
        Metric("objective_demo.max_link.max_link", rtol=DET),
        Metric("objective_demo.hotspot_peak_reduction", rtol=DET),
        # deterministic work counters from the suite-wide recorder: a changed
        # dispatch or eval count means the search loops did different work
        Metric("counters.deploy_deployments", rtol=DET),
        Metric("counters.noc_batch_dispatches", rtol=DET),
        Metric("counters.noc_batch_evals", rtol=DET),
    ],
    "device_search": [
        # O(degree) delta-cost parity: exact vs full re-evaluation on the
        # integer-volume model graph, Pallas kernel vs numpy in float32
        Metric("delta_parity.numpy_exact", expect=True),
        Metric("delta_parity.numpy_max_abs_err", max_abs=1e-9),
        Metric("delta_parity.pallas_max_rel_err", max_abs=1e-5),
        # timings are never gated — the *booleans* derived from them are:
        # the one-dispatch SA must clear its smoke speedup floor, and the
        # vmapped restart fan-out must beat the single chain at far below
        # linear wall-time scaling
        Metric("headline.speedup_ok", expect=True),
        Metric("restarts.restarts_improve_ok", expect=True),
        Metric("restarts.restarts_wall_ok", expect=True),
        Metric("recorder_identity.results_identical", expect=True),
        # device best costs are jax(float32)-backed: wide band like PPO
        Metric("headline.device_comm_cost", rtol=PPO_BAND),
        Metric("restarts.curve.1.best_cost", rtol=PPO_BAND),
        Metric("ga.device_comm_cost", rtol=PPO_BAND),
        # host references on the same shape stay numpy-deterministic
        Metric("headline.host_comm_cost", rtol=DET),
        Metric("ga.host_comm_cost", rtol=DET),
        Metric("counters.sa_accepted", rtol=PPO_BAND),
    ],
    "multilevel": [
        # timings never gated; the derived booleans are: the V-cycle must
        # clear its smoke speedup floor vs flat SA at equal-or-better cost,
        # and the 16k-node placement must complete validly
        Metric("headline.speedup_ok", expect=True),
        Metric("headline.cost_ok", expect=True),
        Metric("large.completed", expect=True),
        Metric("large.valid", expect=True),
        # the V-cycle and the flat host SA are numpy-deterministic
        Metric("headline.flat_comm_cost", rtol=DET),
        Metric("headline.ml_comm_cost", rtol=DET),
        Metric("large.comm_cost", rtol=DET),
        Metric("large.n_levels", rtol=DET),
        Metric("identity.delegation_identical", expect=True),
        Metric("recorder_identity.results_identical", expect=True),
        Metric("counters.ml_levels", rtol=DET),
    ],
    "multichip": [
        Metric("cases.0.comm_cost", rtol=DET),                 # zigzag
        Metric("cases.1.comm_cost", rtol=DET),                 # sigmate
        Metric("cases.2.comm_cost", rtol=DET),                 # random_search
        Metric("cases.3.comm_cost", rtol=DET),                 # sim. annealing
        Metric("cases.4.comm_cost", rtol=DET),                 # genetic
        Metric("cases.4.interchip_bytes", rtol=DET),
        Metric("cases.5.comm_cost", rtol=PPO_BAND),            # ppo (jax)
        Metric("cases.6.interchip_bytes", rtol=DET),           # genetic+ic
        Metric("counters.noc_batch_dispatches", rtol=DET),
        Metric("counters.noc_batch_evals", rtol=DET),
    ],
    "copartition": [
        Metric("grids.0.cases.0.interchip_bytes", rtol=DET),   # balanced
        Metric("grids.0.cases.0.makespan_s", rtol=DET),
        Metric("grids.0.cases.1.interchip_bytes", rtol=DET),   # chip
        Metric("grids.0.cases.1.makespan_s", rtol=DET),
        Metric("grids.0.cases.1.partition_cut_bytes", rtol=DET),
        Metric("grids.0.cases.3.interchip_bytes", rtol=DET),   # chip+copart
        Metric("counters.noc_batch_evals", rtol=DET),
    ],
    "service": [
        # serving-layer acceptance bits: cached answers must clear the 50x
        # speedup floor over the cold p50, warm near-misses must land within
        # the cost band at under half the cold wall, fused batch rows must
        # be bit-identical to their solo cold searches, and a reloaded cache
        # must still hit. Raw latency percentiles are recorded, never gated —
        # except the hit p50's generous absolute ceiling (a hit is a hash +
        # dict lookup; 50 ms of slack is orders of magnitude).
        Metric("hit.all_hits", expect=True),
        Metric("hit.matches_cold", expect=True),
        Metric("hit.speedup_ok", expect=True),
        Metric("hit.p50_s", max_abs=0.05),
        Metric("warm.status_warm", expect=True),
        Metric("warm.cost_ok", expect=True),
        Metric("warm.time_ok", expect=True),
        Metric("fused.results_match", expect=True),
        Metric("persistence.hit_after_reload", expect=True),
        # the seeded SA searches behind the service are numpy-deterministic
        Metric("cold.objective_cost", rtol=DET),
        Metric("warm.objective_cost", rtol=DET),
        Metric("warm.attempts", rtol=DET),
        Metric("fused.costs.0", rtol=DET),
        Metric("fused.costs.3", rtol=DET),
        # deterministic service work counters (hits/misses/warm/fused rows)
        Metric("counters.service_requests", rtol=DET),
        Metric("counters.service_hits", rtol=DET),
        Metric("counters.service_misses", rtol=DET),
        Metric("counters.service_warm_starts", rtol=DET),
        Metric("counters.service_fused_rows", rtol=DET),
    ],
    "fault_replace": [
        # the online re-placement loop is fully deterministic (seeded SA on
        # the batch backend, analytic drift): gate the recovery outcomes,
        # the acceptance window, and the loop's algorithmic work counters
        Metric("acceptance.link_drop_triggered_replacement", expect=True),
        Metric("acceptance.warm_within_10pct_of_cold", expect=True),
        Metric("acceptance.warm_moves_at_most_25pct_of_cold_bytes",
               expect=True),
        Metric("recorder_identity.results_identical", expect=True),
        Metric("scenarios.link_drop.final_objective", rtol=DET),
        Metric("scenarios.link_drop.moved_state_bytes", rtol=DET),
        Metric("scenarios.link_drop.recoveries.0.objective_after", rtol=DET),
        Metric("scenarios.drift.final_objective", rtol=DET),
        Metric("scenarios.node_drop.final_objective", rtol=DET),
        Metric("scenarios.node_drop.n_replacements", rtol=DET),
        Metric("counters.noc_batch_evals", rtol=DET),
        Metric("counters.runtime_replacements", rtol=DET),
    ],
}


def _run_suite(name: str, json_path: str) -> None:
    """Run one suite's smoke mode in-process, record written to json_path."""
    from . import (copartition, deploy_e2e, device_search, fault_replace,
                   multichip, multilevel, noc_eval, ppo_pipeline, service)
    fn = {"noc_eval": noc_eval.noc_eval,
          "ppo_pipeline": ppo_pipeline.ppo_pipeline,
          "deploy_e2e": deploy_e2e.deploy_e2e,
          "device_search": device_search.device_search,
          "multilevel": multilevel.multilevel,
          "multichip": multichip.multichip,
          "copartition": copartition.copartition,
          "fault_replace": fault_replace.fault_replace,
          "service": service.service}[name]
    for row in fn(smoke=True, json_path=json_path):
        print(f"  {row[0]},{row[1]:.1f},{row[2]}")


def baseline_path(name: str, baseline_dir: str) -> str:
    return os.path.join(baseline_dir, f"BENCH_{name}_smoke.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_regression",
        description="Run benchmark smoke suites and gate headline metrics "
                    "against the committed results/BENCH_*_smoke.json "
                    "baselines.")
    ap.add_argument("--suites", default=",".join(SUITES),
                    help=f"comma list from {tuple(SUITES)}")
    ap.add_argument("--out-dir", default="smoke-results",
                    help="where fresh smoke records are written "
                         "(uploaded as CI artifacts)")
    ap.add_argument("--baseline-dir", default=RESULTS_DIR,
                    help="directory holding BENCH_<suite>_smoke.json")
    ap.add_argument("--update-baselines", action="store_true",
                    help="write the fresh records as the new committed "
                         "baselines instead of gating")
    args = ap.parse_args(argv)

    names = [s for s in args.suites.split(",") if s]
    unknown = [s for s in names if s not in SUITES]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {tuple(SUITES)}")

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for name in names:
        fresh_path = os.path.join(args.out_dir, f"BENCH_{name}_smoke.json")
        print(f"[{name}] running smoke...")
        try:
            _run_suite(name, fresh_path)
            with open(fresh_path) as f:
                fresh = json.load(f)
        except Exception:  # noqa: BLE001 — a crashing suite must fail the gate
            traceback.print_exc()
            print(f"[{name}] FAIL (suite crashed)")
            failures += 1
            continue

        base_file = baseline_path(name, args.baseline_dir)
        if args.update_baselines:
            os.makedirs(args.baseline_dir, exist_ok=True)
            with open(base_file, "w") as f:
                json.dump(fresh, f, indent=2)
            print(f"[{name}] baseline updated -> {base_file}")
            continue

        baseline = None
        if os.path.exists(base_file):
            with open(base_file) as f:
                baseline = json.load(f)
        verdicts = compare_suite(SUITES[name], fresh, baseline)
        bad = [v for v in verdicts if v["status"] == "fail"]
        for v in verdicts:
            mark = {"ok": "ok  ", "fail": "FAIL", "skip": "skip"}[v["status"]]
            print(f"  [{mark}] {v['path']}"
                  + (f": {v['detail']}" if v["detail"] else ""))
        if bad:
            failures += 1
            print(f"[{name}] FAIL ({len(bad)} metric(s) out of band)")
        else:
            print(f"[{name}] ok")

    if failures:
        print(f"regression gate: {failures} suite(s) failed "
              "(if the change is intentional, regenerate baselines with "
              "--update-baselines and commit them)")
        return 1
    print("regression gate: all suites within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
