"""Event-driven spike-matmul kernel: when does tile-level skipping pay?

Hardware-adaptation finding (recorded in DESIGN.md §2/§7): with *uniform-random*
spikes at the paper's densities, the probability that a whole MXU tile
(128×128, or even 8×128) is all-zero is ~0 — synapse-granular event skipping
(the paper's selector+adder FP engine) does NOT transfer to tile-granular MXU
skipping. It DOES pay under *structured* sparsity: silent channels / dead
feature maps zero out contiguous k-columns of the im2col matrix. Both regimes
are measured below; the structured case uses channel-major im2col layout with
blocks aligned to channel groups.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _skip_fraction(spikes, bm, bk):
    m, k = spikes.shape
    m2, k2 = m - m % bm, k - k % bk
    blocks = spikes[:m2, :k2].reshape(m2 // bm, bm, k2 // bk, bk).any(
        axis=(1, 3))
    return 1.0 - blocks.mean()


def spike_kernel():
    rows = []
    rng = np.random.default_rng(0)
    # conv3 of S-ResNet18: im2col lhs [B*H*W, Cin*9], channel-major features
    m, cin, kk = 4096, 128, 9
    k = cin * kk
    for density in (0.05, 0.15):
        sp = rng.random((m, k)) < density             # uniform-random spikes
        frac_u = _skip_fraction(sp, 8, 128)
        rows.append((
            f"spike_kernel.uniform.d{density}", 0.0,
            f"skipped_8x128_tiles={100*frac_u:.1f}% (uniform spikes do NOT "
            f"zero tiles - negative result, see DESIGN.md)"))
    for silent in (0.5, 0.75, 0.9):
        active = rng.random(cin) >= silent            # structured: dead channels
        sp = (rng.random((m, k)) < 0.3) & np.repeat(active, kk)[None, :]
        # blocks aligned to channel groups: bk = 9*16 columns = 16 channels
        frac_s = _skip_fraction(sp, 128, kk * 16)
        rows.append((
            f"spike_kernel.structured.silent{silent}", 0.0,
            f"skipped_128x144_tiles={100*frac_s:.1f}% -> MXU passes x"
            f"{1/(1-frac_s+1e-9):.2f} fewer (channel-aligned blocks)"))
    # interpret-mode correctness+timing point
    from repro.kernels import ops, ref
    sp = (jax.random.uniform(jax.random.PRNGKey(0), (256, 256)) < 0.1
          ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    out = ops.spike_matmul(sp, w)                # compile+run once
    t0 = time.time()
    out = ops.spike_matmul(sp, w).block_until_ready()
    us = (time.time() - t0) * 1e6
    err = float(jnp.abs(out - ref.spike_matmul_ref(sp, w)).max())
    rows.append(("spike_kernel.interpret.256x256x128", us,
                 f"max_err={err:.2e} (interpret-mode on CPU)"))
    return rows
