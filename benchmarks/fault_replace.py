"""Benchmark: online re-placement under faults and traffic drift.

Feeds the :mod:`repro.deploy.runtime` control loop three scenarios on a
multi-chip HierarchicalMesh and records every monitor sample and recovery
decision:

* ``link_drop`` — the headline: deploy, find the seeded placement's busiest
  inter-chip link, drop it mid-scenario, and let the loop recover with a
  migration-penalized warm re-place (``compare_cold=True`` runs the
  from-scratch re-optimization next to it — the acceptance data);
* ``drift``     — diurnal traffic drift only (no faults): the loop re-places
  when the shifting pattern degrades the live placement past the threshold;
* ``node_drop`` — a core dies and is later repaired: both events change chip
  capacities, so the loop re-runs the whole partition->place flow on the
  degraded fabric.

Acceptance (ISSUE 7): on the full ``hier:2x2:4x4`` system, dropping the
busiest inter-chip link triggers a re-placement whose objective lands within
10% of the cold re-optimization while moving at most 25% of the state bytes
the cold option would migrate. The emitted ``results/BENCH_fault_replace.json``
carries the ``acceptance`` block; ``--smoke`` runs the seconds-scale version
(2×2 chips of 2×2, S-ResNet18) whose committed baseline gates CI.

The record also pins ``recorder_identity.results_identical``: replaying the
headline scenario with the recorder attached and detached must produce
bit-identical ScenarioResults (the control loop reads deterministic objective
values and seeded RNG streams only).
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from .common import SPIKE_MODELS, counter_record, write_record, write_trace
from repro.core import HierarchicalMesh
from repro.deploy import deploy_model
from repro.deploy.runtime import run_scenario
from repro.obs import Recorder

# The tuned operating point of the warm re-placement (tests/test_runtime.py
# asserts the acceptance window at the same settings). The initial deployment
# gets 16x the warm budget so the live placement starts converged and the
# recovery responds to the fault, not to leftover optimization slack; warm
# repair anneals much cooler than a from-scratch SA (t0) so it perturbs the
# live placement locally instead of scrambling it.
THRESHOLD = 0.02
MIGRATION_WEIGHT = 0.12
WARM_T0 = 0.005
DEPLOY_FACTOR = 16


def _busiest_interchip_link(hm, cfg, budget: int) -> int:
    """Link id of the hottest inter-chip link under the seeded deployment
    (the same deploy run_scenario performs first, so the drop is guaranteed
    to hit live traffic)."""
    plan = deploy_model(cfg, hm, method="simulated_annealing", seed=0,
                        budget=budget, schedule="none")
    m = hm.evaluate(plan.graph, np.asarray(plan.placement.placement,
                                           dtype=int))
    loads = np.zeros(hm.n_links)
    for label, vol in m.link_traffic.items():
        loads[hm.link_id_of(label)] = vol
    ic = hm.interchip_mask()
    return int(np.argmax(np.where(ic, loads, -1.0)))


def _scenario_row(name: str, res) -> tuple:
    return (
        f"fault_replace.{name}", 0.0,
        f"replacements={res.n_replacements} cold={res.n_cold_fallbacks} "
        f"moved_MB={res.moved_state_bytes / 1e6:.2f} "
        f"max_deg={100 * res.max_degradation:+.1f}% "
        f"final={res.final_objective:.3e}")


def fault_replace(smoke: bool = False, json_path: str | None = None):
    if smoke:
        hm = HierarchicalMesh(2, 2, 2, 2, link_bw=8e9, core_flops=25.6e9,
                              hop_latency=2e-8)
        model, budget, dead_core = "S-ResNet18", 512, 5
    else:
        hm = HierarchicalMesh(2, 2, 4, 4, link_bw=8e9, core_flops=25.6e9,
                              hop_latency=2e-8)
        model, budget, dead_core = "S-VGG16", 4096, 21
    cfg = SPIKE_MODELS[model]()
    deploy_budget = budget * DEPLOY_FACTOR
    lid = _busiest_interchip_link(hm, cfg, deploy_budget)

    recorder = Recorder()
    common = dict(method="simulated_annealing", objective="comm_cost",
                  budget=budget, deploy_budget=deploy_budget,
                  migration_weight=MIGRATION_WEIGHT,
                  warm_kw={"t0": WARM_T0}, seed=0)

    link_scen = f"steps=6;fault=link:{lid}@2"
    link_res = run_scenario(cfg, hm, link_scen, threshold=THRESHOLD,
                            compare_cold=True, cold_budget=deploy_budget,
                            recorder=recorder, **common)
    drift_res = run_scenario(cfg, hm, "steps=8;drift=diurnal:0.4:8",
                             threshold=0.15, recorder=recorder, **common)
    node_scen = f"steps=5;fault=node:{dead_core}@1;repair=node:{dead_core}@3"
    node_res = run_scenario(cfg, hm, node_scen, threshold=0.15,
                            recorder=recorder, **common)

    # recorder on/off must leave the scenario bit-identical (compare the
    # serialized results of a detached and an attached replay)
    res_off = run_scenario(cfg, hm, link_scen, threshold=THRESHOLD, **common)
    res_on = run_scenario(cfg, hm, link_scen, threshold=THRESHOLD,
                          recorder=Recorder(), **common)
    identical = res_off.to_dict() == res_on.to_dict()

    rec = link_res.recoveries[0] if link_res.recoveries else None
    cold = (rec or {}).get("cold_reference")
    acceptance = {
        "link_drop_triggered_replacement": link_res.n_replacements >= 1,
        "warm_within_10pct_of_cold":
            bool(rec and cold
                 and rec["objective_after"] <= 1.10 * cold["objective"]),
        "warm_moves_at_most_25pct_of_cold_bytes":
            bool(rec and cold and rec["moved_state_bytes"]
                 <= 0.25 * cold["moved_state_bytes"]),
        "warm_over_cold_objective":
            rec["objective_after"] / cold["objective"] if rec and cold
            else None,
        "warm_moved_fraction_of_cold":
            rec["moved_state_bytes"] / cold["moved_state_bytes"]
            if rec and cold and cold["moved_state_bytes"] else None,
    }

    record = {
        "smoke": smoke,
        "topology": hm.describe(),
        "model": model,
        "budget": budget,
        "deploy_budget": deploy_budget,
        "threshold": THRESHOLD,
        "migration_weight": MIGRATION_WEIGHT,
        "warm_t0": WARM_T0,
        "busiest_interchip_link": lid,
        "scenarios": {
            "link_drop": link_res.to_dict(),
            "drift": drift_res.to_dict(),
            "node_drop": node_res.to_dict(),
        },
        "acceptance": acceptance,
        "recorder_identity": {"results_identical": identical},
        "counters": counter_record(recorder),
    }

    rows = [("fault_replace.busiest_link", 0.0,
             f"link={lid} (interchip) scenario={link_scen!r}")]
    for name, res in (("link_drop", link_res), ("drift", drift_res),
                      ("node_drop", node_res)):
        rows.append(_scenario_row(name, res))
    if rec and cold:
        rows.append((
            "fault_replace.acceptance", 0.0,
            f"warm/cold={acceptance['warm_over_cold_objective']:.3f} "
            f"moved_frac={acceptance['warm_moved_fraction_of_cold']:.3f} "
            f"within10={acceptance['warm_within_10pct_of_cold']} "
            f"moved<=25={acceptance['warm_moves_at_most_25pct_of_cold_bytes']}"
        ))
    rows.append(("fault_replace.recorder_identity", 0.0,
                 f"identical={identical}"))
    out = write_record(record, json_path, smoke, "BENCH_fault_replace.json")
    if out:
        rows.append(("fault_replace.json", 0.0,
                     f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "fault_replace", json_path, smoke)
    if tr:
        rows.append(("fault_replace.trace", 0.0,
                     f"wrote {os.path.relpath(tr)}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI subset (tiny chips/budgets)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in fault_replace(smoke=args.smoke,
                                           json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
