"""Benchmark: the end-to-end deployment engine (`repro.deploy`).

Runs ``deploy_model`` — profile -> partition -> place -> schedule — for the
paper's models on the 32-core grid, across placement methods and objectives,
and records per-stage wall times plus the deployed metrics. Also measures the
multi-objective payoff: simulated annealing under the ``max_link`` objective
vs the comm-cost optimum (hotspot peak reduction), and an energy-weighted
combo. Emits ``results/BENCH_deploy_e2e.json`` and run.py CSV rows;
``--smoke`` runs a seconds-scale subset (no JSON) for CI.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from .common import (SPIKE_MODELS, bench_percentiles, counter_record,
                     make_noc, model_graph, write_record, write_trace)

from repro.core.placement import optimize_placement  # noqa: E402
from repro.core.placement.ppo import PPOConfig  # noqa: E402
from repro.deploy import deploy_model  # noqa: E402
from repro.obs import Recorder  # noqa: E402

ENERGY_COMBO = {"comm_cost": 1.0, "energy": 2e9}


def _case(model_name, model_cfg, noc, method, objective, budget=None,
          recorder=None, **kw):
    # **kw may itself carry a cfg= (e.g. a PPOConfig) for optimize_placement
    plan = deploy_model(model_cfg, noc, method=method, objective=objective,
                        schedule="fpdeep", n_units=8, budget=budget,
                        recorder=recorder, **kw)
    rep = plan.report()
    rep["model"] = model_name
    total = sum(rep["stage_times_s"].values())
    rep["total_s"] = total
    return plan, rep


def deploy_e2e(smoke: bool = False, json_path: str | None = None):
    if smoke:
        models = ["S-ResNet18"]
        methods = [("zigzag", {}), ("random_search", {"budget": 64})]
        sa_budget = 200
    else:
        models = ["S-VGG16", "S-ResNet18"]
        methods = [
            ("zigzag", {}),
            ("sigmate", {}),
            ("random_search", {"budget": 1500}),
            ("simulated_annealing", {"budget": 4000}),
            ("ppo", {"cfg": PPOConfig(batch_size=48, iterations=15,
                                      ppo_epochs=4, seed=0)}),
        ]
        sa_budget = 4000
    noc = make_noc(32)

    # one recorder across the whole suite: every deployment's stage spans and
    # search trajectory land in one TRACE_deploy_e2e.jsonl artifact, and the
    # work counters (deployments, scorer dispatches/evals) are
    # seed-deterministic — check_regression gates them
    recorder = Recorder()
    record = {"smoke": smoke, "cases": [], "objective_demo": {}}
    rows_out = []
    for model_name in models:
        cfg = SPIKE_MODELS[model_name]()
        for method, kw in methods:
            _, rep = _case(model_name, cfg, noc, method, "comm_cost",
                           recorder=recorder, **kw)
            record["cases"].append(rep)
            st = rep["stage_times_s"]
            rows_out.append((
                f"deploy_e2e.{model_name}.{method}",
                rep["total_s"] * 1e6,
                f"comm={rep['placement']['comm_cost']:.3e} "
                f"profile={st['profile']*1e3:.1f}ms "
                f"partition={st['partition']*1e3:.1f}ms "
                f"place={st['place']:.2f}s "
                f"schedule={st['schedule']*1e3:.1f}ms"))

    # ---- multi-objective payoff (paper Fig 7 hotspot story) -------------
    # same searcher + budget + seed, three objectives; the hotspot-aware
    # optimum must flatten the peak link the comm-cost optimum leaves hot
    demo_model = models[0]
    cfg = SPIKE_MODELS[demo_model]()
    by_obj = {}
    for objective in ("comm_cost", "max_link", ENERGY_COMBO):
        plan, rep = _case(demo_model, cfg, noc, "simulated_annealing",
                          objective, budget=sa_budget, recorder=recorder)
        key = rep["placement"]["objective"]
        by_obj[key] = (plan, rep)
        record["objective_demo"][key] = rep["placement"]
    comm = by_obj["comm_cost"][1]["placement"]
    ml = by_obj["max_link"][1]["placement"]
    reduction = comm["max_link"] / max(ml["max_link"], 1e-30)
    placements_differ = not np.array_equal(
        by_obj["comm_cost"][0].placement.placement,
        by_obj["max_link"][0].placement.placement)
    record["objective_demo"]["hotspot_peak_reduction"] = reduction
    record["objective_demo"]["placements_differ"] = placements_differ
    rows_out.append((
        f"deploy_e2e.objective_demo.{demo_model}", 0.0,
        f"max_link obj cuts peak link x{reduction:.2f} vs comm optimum "
        f"(placements_differ={placements_differ})"))

    # ---- placement latency distribution (p50/p99, not just the mean) ----
    # the `place` stage dominates sweep wall time; measure its distribution
    # for the host SA and the device-resident SA (single chain and a
    # 16-restart fan-out) at the suite's shape. recorder=None on purpose:
    # these extra runs must not move the suite's gated work counters.
    graph, _ = model_graph(demo_model, 32)
    repeats = 5 if smoke else 20
    lat = {}
    for label, okw in (
            ("sa_batch", {}),
            ("sa_device", {"backend": "device"}),
            ("sa_device_r16", {"backend": "device", "restarts": 16})):
        def place(okw=okw):
            optimize_placement(graph, noc, method="simulated_annealing",
                               seed=0, budget=sa_budget, **okw)
        lat[label] = bench_percentiles(place, repeats=repeats, warmup=1)
    record["placement_latency"] = lat
    rows_out.append((
        "deploy_e2e.placement_latency", lat["sa_batch"]["p50"] * 1e6,
        " ".join(f"{k}:p50={v['p50']*1e3:.1f}ms,p99={v['p99']*1e3:.1f}ms"
                 for k, v in lat.items())))

    record["counters"] = counter_record(recorder)
    rows_out.append(("deploy_e2e.counters", 0.0,
                     " ".join(f"{k}={v:g}"
                              for k, v in sorted(record["counters"].items()))))

    out = write_record(record, json_path, smoke, "BENCH_deploy_e2e.json")
    if out:
        rows_out.append(("deploy_e2e.json", 0.0,
                         f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "deploy_e2e", json_path, smoke)
    if tr:
        rows_out.append(("deploy_e2e.trace", 0.0,
                         f"wrote {os.path.relpath(tr)}"))
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in deploy_e2e(smoke=args.smoke,
                                        json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
