"""Benchmark: placement service (`repro.deploy.service`).

Pins the serving layer's headline claims on a 16-core fabric with the
S-ResNet18 deployment request:

* **cache hits** — repeating an identical :class:`DeployRequest` must be
  answered from the :class:`PlanCache` at >= 50x below the cold-search p50
  (the PR's acceptance floor), returning the bit-identical plan.
* **warm near-miss** — a request sharing the donor's ``warm_key`` (same
  model/topology/partition, different seed) warm-starts from the cached
  placement: final cost within 5% of the full cold search on that request,
  at <= 50% of its wall time.
* **fused batches** — k concurrent cold same-graph requests run as rows of
  one batched-scorer dispatch and every row must match its *solo cold*
  ``execute_request`` result bit-for-bit (batching is throughput-only).
* **persistence** — a cache saved to JSON and reloaded in a fresh service
  still answers the original request as a hit.

Timings are machine-dependent so the regression gate never compares them —
it gates the derived booleans (``speedup_ok``, ``cost_ok``, ``time_ok``,
``results_match``, ``hit_after_reload``), an absolute ceiling on the hit
p50 (a hit is a hash + dict lookup; 50 ms of slack is three orders of
magnitude), the numpy-deterministic seeded costs at the tight band, and
the service's deterministic hit/miss/warm/fused work counters.

Emits ``results/BENCH_service.json`` and run.py CSV rows.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from .common import (CORE_FLOPS, HOP_LAT, LINK_BW, SPIKE_MODELS,
                     counter_record, percentiles, timed, write_record,
                     write_trace)

from repro.core import NoC  # noqa: E402
from repro.deploy import (DeployRequest, PlacementService,  # noqa: E402
                          PlanCache, execute_request)
from repro.obs import Recorder  # noqa: E402

# large enough that the search loop dominates the per-request fixed costs
# (profiling + partitioning + scorer build) — the warm wall-ratio band is
# only meaningful when the budget fraction is what drives the wall time
SA_BUDGET = {"smoke": 4000, "full": 12000}
COLD_REPEATS = {"smoke": 5, "full": 12}
HIT_REPEATS = {"smoke": 40, "full": 300}
WARM_REPEATS = {"smoke": 5, "full": 7}
SPEEDUP_FLOOR = 50.0          # acceptance: cached >= 50x faster than cold p50
WARM_COST_BAND = 1.05         # acceptance: warm cost <= 105% of cold cost
WARM_WALL_BAND = 0.5          # acceptance: warm wall <= 50% of cold wall
FUSE_ROWS = 4
NEAR_MISS_SEED = 777


def service(smoke: bool = False, json_path: str | None = None):
    mode = "smoke" if smoke else "full"
    budget = SA_BUDGET[mode]
    recorder = Recorder()
    record = {"smoke": smoke}
    rows_out = []

    noc = NoC(4, 4, torus=False, link_bw=LINK_BW, core_flops=CORE_FLOPS,
              hop_latency=HOP_LAT)
    cfg = SPIKE_MODELS["S-ResNet18"]()

    def make_req(seed: int) -> DeployRequest:
        return DeployRequest.from_call(
            cfg, noc, partition_strategy="balanced",
            method="simulated_annealing", objective="comm_cost",
            schedule="none", budget=budget, seed=seed)

    record["setup"] = {"n_cores": noc.n_cores, "model": "S-ResNet18",
                       "method": "simulated_annealing", "budget": budget,
                       "cache_key": make_req(0).cache_key()}

    # ---- cold: every request a genuine miss (fresh service each) ---------
    cold_lat, cold_cost = [], None
    for s in range(COLD_REPEATS[mode]):
        resp = PlacementService(recorder=recorder).submit(make_req(s))
        cold_lat.append(resp.latency_s)
        if s == 0:
            cold_cost = resp.objective_cost
    cold = percentiles(cold_lat)
    record["cold"] = {"n": len(cold_lat), "p50_s": cold["p50"],
                      "p99_s": cold["p99"], "objective_cost": cold_cost}
    rows_out.append(("service.cold", cold["p50"] * 1e6,
                     f"n={len(cold_lat)} p50={cold['p50']*1e3:.1f}ms "
                     f"p99={cold['p99']*1e3:.1f}ms cost={cold_cost:.3e}"))

    # ---- hits: one persistent service, identical request repeated --------
    svc = PlacementService(recorder=recorder)
    first = svc.submit(make_req(0))                       # populate: miss
    hits = [svc.submit(make_req(0)) for _ in range(HIT_REPEATS[mode])]
    all_hits = all(r.status == "hit" for r in hits)
    hit = percentiles([r.latency_s for r in hits])
    speedup = cold["p50"] / max(hit["p50"], 1e-12)
    record["hit"] = {
        "n": len(hits), "p50_s": hit["p50"], "p99_s": hit["p99"],
        "all_hits": all_hits,
        "matches_cold": bool(hits[-1].objective_cost == cold_cost
                             and first.status == "miss"),
        "objective_cost": hits[-1].objective_cost,
        "speedup_p50": speedup, "speedup_floor": SPEEDUP_FLOOR,
        "speedup_ok": speedup >= SPEEDUP_FLOOR,
    }
    rows_out.append(("service.hit", hit["p50"] * 1e6,
                     f"n={len(hits)} p50={hit['p50']*1e6:.0f}us "
                     f"p99={hit['p99']*1e6:.0f}us speedup=x{speedup:.0f} "
                     f"(floor x{SPEEDUP_FLOOR:g}) ok={all_hits and record['hit']['speedup_ok']}"))

    # ---- warm near-miss: same warm_key, new seed --------------------------
    # each repeat gets a fresh cache holding only the donor entry, so the
    # warm search always starts from the same donor (no self-feeding)
    donor_req = make_req(0)
    donor_plan = execute_request(donor_req)
    miss_req = make_req(NEAR_MISS_SEED)

    def run_warm():
        c = PlanCache()
        c.put(donor_req, donor_plan)
        return PlacementService(cache=c, recorder=recorder).submit(miss_req)

    warm_resps = [run_warm() for _ in range(WARM_REPEATS[mode])]
    warm = percentiles([r.latency_s for r in warm_resps])
    wr = warm_resps[0]
    cold_ref, cold_ref_lat = None, []
    for _ in range(WARM_REPEATS[mode]):
        cold_ref, us = timed(execute_request, miss_req)
        cold_ref_lat.append(us / 1e6)
    cold_ref_p50 = percentiles(cold_ref_lat)["p50"]
    cost_ratio = wr.objective_cost / cold_ref.placement.objective_cost
    # the CI-gated wall ratio compares best-of-N timings: min is robust to
    # transient load spikes that would skew a 3-sample p50 on ~25 ms runs,
    # while still measuring the same warm-vs-cold compute ratio (p50s are
    # recorded alongside for the latency report)
    wall_ratio = min(r.latency_s for r in warm_resps) / max(min(cold_ref_lat),
                                                            1e-12)
    record["warm"] = {
        "n": len(warm_resps),
        "status_warm": all(r.status == "warm" for r in warm_resps),
        "attempts": wr.attempts, "warm_from": wr.warm_from,
        "objective_cost": wr.objective_cost,
        "donor_cost": donor_plan.placement.objective_cost,
        "cold_cost": cold_ref.placement.objective_cost,
        "cost_ratio": cost_ratio, "cost_band": WARM_COST_BAND,
        "cost_ok": cost_ratio <= WARM_COST_BAND,
        "p50_s": warm["p50"], "cold_p50_s": cold_ref_p50,
        "wall_ratio": wall_ratio, "wall_band": WARM_WALL_BAND,
        "time_ok": wall_ratio <= WARM_WALL_BAND,
    }
    rows_out.append(("service.warm", warm["p50"] * 1e6,
                     f"attempts={wr.attempts} cost_ratio={cost_ratio:.3f} "
                     f"(band {WARM_COST_BAND:g}) wall_ratio={wall_ratio:.2f} "
                     f"(band {WARM_WALL_BAND:g}) "
                     f"ok={record['warm']['cost_ok'] and record['warm']['time_ok']}"))

    # ---- fused batch vs solo cold ----------------------------------------
    fuse_reqs = [make_req(100 + i) for i in range(FUSE_ROWS)]
    svc_f = PlacementService(recorder=recorder)
    t0 = time.perf_counter()
    fused = svc_f.submit_batch(fuse_reqs)
    batch_wall = time.perf_counter() - t0
    serial_wall, match = 0.0, True
    for req, resp in zip(fuse_reqs, fused):
        solo, us = timed(execute_request, req)
        serial_wall += us / 1e6
        match = match and bool(
            resp.fused
            and np.array_equal(np.asarray(resp.placement),
                               solo.placement.placement)
            and resp.objective_cost == solo.placement.objective_cost)
    record["fused"] = {
        "rows": FUSE_ROWS, "results_match": match,
        "batch_wall_s": batch_wall, "serial_wall_s": serial_wall,
        "throughput_rps": FUSE_ROWS / max(batch_wall, 1e-12),
        "serial_rps": FUSE_ROWS / max(serial_wall, 1e-12),
        "costs": [r.objective_cost for r in fused],
    }
    rows_out.append(("service.fused", batch_wall / FUSE_ROWS * 1e6,
                     f"rows={FUSE_ROWS} batch={batch_wall:.2f}s "
                     f"serial={serial_wall:.2f}s "
                     f"throughput={record['fused']['throughput_rps']:.1f}rps "
                     f"bit_identical={match}"))

    # ---- persistence: save -> reload -> hit -------------------------------
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plans.json")
        svc.cache.save(path)
        svc2 = PlacementService(cache=PlanCache.load(path), recorder=recorder)
        reloaded = svc2.submit(make_req(0))
    record["persistence"] = {
        "hit_after_reload": bool(reloaded.status == "hit"
                                 and reloaded.objective_cost == cold_cost),
    }
    rows_out.append(("service.persistence", reloaded.latency_s * 1e6,
                     f"hit_after_reload={record['persistence']['hit_after_reload']}"))

    record["counters"] = counter_record(recorder)
    record["latency"] = recorder.histogram_summaries()

    out = write_record(record, json_path, smoke, "BENCH_service.json")
    if out:
        rows_out.append(("service.json", 0.0, f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "service", json_path, smoke)
    if tr:
        rows_out.append(("service.trace", 0.0, f"wrote {os.path.relpath(tr)}"))
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in service(smoke=args.smoke, json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
