"""Benchmark: device-resident search (`repro.core.placement.device_search`).

Pins the PR's headline at the ``BENCH_deploy_e2e`` shape (S-ResNet18 sliced
to the 32-core grid, budget 4000): one-dispatch scanned SA vs the host
``backend="batch"`` sequential SA, the restarts-vs-quality curve (vmapped
parallel chains — 64 chains must beat the single chain at well under 64x its
wall time), device GA vs host genetic, and the O(degree) delta-cost parity
bits (numpy exact on integer volumes; Pallas kernel vs numpy in float32).

Timings are machine-dependent so the regression gate never compares them —
it gates the derived *booleans* (``speedup_ok``, ``restarts_improve_ok``,
``restarts_wall_ok``, parity bits, recorder identity) plus the device best
costs at a wide jax band. ``--smoke`` runs a seconds-scale subset with a
conservative speedup threshold so noisy CI runners don't flake.

Emits ``results/BENCH_device_search.json`` and run.py CSV rows.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from .common import (bench_percentiles, counter_record, make_noc,
                     model_graph, write_record, write_trace)

from repro.core.noc_batch import (build_incident_tables, delta_comm_cost,
                                  evaluate_batch)  # noqa: E402
from repro.core.placement import optimize_placement  # noqa: E402
from repro.core.placement.device_search import (  # noqa: E402
    genetic_device, simulated_annealing_device)
from repro.obs import Recorder  # noqa: E402

BUDGET = 4000                 # matches the deploy_e2e SA budget
# full runs must hold the PR's >=10x headline; smoke gates a conservative
# floor so a loaded CI runner doesn't flake the gate
SPEEDUP_FLOOR = {"full": 10.0, "smoke": 4.0}
WALL_RATIO_CEILING = 8.0      # max-restarts wall time vs single chain


def _comm(noc, graph, placement) -> float:
    return float(evaluate_batch(noc, graph,
                                np.asarray(placement)[None]).comm_cost[0])


def _delta_parity(noc, graph, swaps: int = 200) -> dict:
    """Numpy O(degree) delta vs full(after) - full(before) over a random
    swap stream, plus the Pallas kernel vs the same numpy reference."""
    from repro.kernels.delta_cost import delta_cost_pallas
    tbl = build_incident_tables(graph)
    rng = np.random.default_rng(0)
    slots = rng.permutation(noc.n_cores)
    max_err = 0.0
    for _ in range(swaps):
        i, j = (int(x) for x in rng.integers(0, slots.size, 2))
        d = delta_comm_cost(noc, graph, slots, i, j, tbl)
        before = _comm(noc, graph, slots[:graph.n])
        slots[i], slots[j] = slots[j], slots[i]
        max_err = max(max_err, abs(d - (_comm(noc, graph, slots[:graph.n])
                                        - before)))

    # Pallas gather/segment-sum kernel vs a dense-indexing float32 reference
    C, K, R = noc.n_cores, 64, 4
    hops = np.asarray(
        [[noc.hops(s, t) for t in range(C)] for s in range(C)],
        dtype=np.float32)
    sb, db, sa_, da = (rng.integers(0, C, (R, K)) for _ in range(4))
    vol = rng.integers(0, 100, (R, K)).astype(np.float32)
    ref = (vol * (hops[sa_, da] - hops[sb, db])).sum(axis=1)
    out = np.asarray(delta_cost_pallas(sb, db, sa_, da, vol, hops,
                                       interpret=True))
    pallas_err = float(np.abs(out - ref).max() / max(np.abs(ref).max(), 1.0))
    return {"numpy_max_abs_err": float(max_err),
            "numpy_exact": max_err == 0.0,
            "pallas_max_rel_err": pallas_err,
            "pallas_ok": pallas_err <= 1e-5}


def device_search(smoke: bool = False, json_path: str | None = None):
    mode = "smoke" if smoke else "full"
    noc = make_noc(32)
    graph, _ = model_graph("S-ResNet18", 32)
    repeats = 3 if smoke else 10
    restart_grid = (1, 16) if smoke else (1, 4, 16, 64)

    record = {"smoke": smoke, "shape": {"model": "S-ResNet18", "n_cores": 32,
                                        "n_nodes": graph.n, "budget": BUDGET}}
    rows_out = []

    # ---- delta-cost parity bits (seed-deterministic, gated exactly) -----
    record["delta_parity"] = _delta_parity(noc, graph,
                                           swaps=60 if smoke else 200)
    rows_out.append((
        "device_search.delta_parity", 0.0,
        f"numpy_exact={record['delta_parity']['numpy_exact']} "
        f"pallas_rel_err={record['delta_parity']['pallas_max_rel_err']:.1e}"))

    # ---- headline: host sequential SA vs one-dispatch device SA ---------
    def host_sa():
        return optimize_placement(graph, noc, method="simulated_annealing",
                                  seed=0, budget=BUDGET)

    def device_sa(restarts=1):
        return optimize_placement(graph, noc, method="simulated_annealing",
                                  backend="device", seed=0, budget=BUDGET,
                                  restarts=restarts)

    host_res = host_sa()
    host_lat = bench_percentiles(host_sa, repeats=repeats, warmup=0)
    dev_res = device_sa()
    dev_lat = bench_percentiles(device_sa, repeats=repeats, warmup=1)
    speedup = host_lat["p50"] / max(dev_lat["p50"], 1e-12)
    record["headline"] = {
        "host_p50_s": host_lat["p50"], "host_p99_s": host_lat["p99"],
        "device_p50_s": dev_lat["p50"], "device_p99_s": dev_lat["p99"],
        "speedup_p50": speedup,
        "speedup_floor": SPEEDUP_FLOOR[mode],
        "speedup_ok": speedup >= SPEEDUP_FLOOR[mode],
        "host_comm_cost": host_res.comm_cost,
        "device_comm_cost": dev_res.comm_cost,
        # float32 device arithmetic vs float64 host on the same schedule:
        # the search qualities must stay comparable even though the RNG
        # streams (numpy vs threefry) necessarily differ
        "cost_ratio_device_over_host": dev_res.comm_cost / host_res.comm_cost,
    }
    rows_out.append((
        "device_search.headline", dev_lat["p50"] * 1e6,
        f"host_p50={host_lat['p50']*1e3:.1f}ms "
        f"device_p50={dev_lat['p50']*1e3:.1f}ms speedup=x{speedup:.1f} "
        f"(floor x{SPEEDUP_FLOOR[mode]:g}, ok={speedup >= SPEEDUP_FLOOR[mode]}) "
        f"cost host={host_res.comm_cost:.3e} dev={dev_res.comm_cost:.3e}"))

    # ---- restarts-vs-quality curve (vmapped parallel chains) ------------
    curve = []
    for r in restart_grid:
        res = device_sa(restarts=r)
        lat = bench_percentiles(lambda r=r: device_sa(restarts=r),
                                repeats=repeats, warmup=1)
        curve.append({"restarts": r, "best_cost": res.comm_cost,
                      "p50_s": lat["p50"],
                      "wall_ratio_vs_r1": lat["p50"] / max(
                          curve[0]["p50_s"] if curve else lat["p50"], 1e-12)})
        rows_out.append((
            f"device_search.restarts_{r}", lat["p50"] * 1e6,
            f"best={res.comm_cost:.3e} p50={lat['p50']*1e3:.1f}ms "
            f"ratio_vs_r1=x{curve[-1]['wall_ratio_vs_r1']:.2f}"))
    rmax = curve[-1]
    record["restarts"] = {
        "grid": list(restart_grid), "curve": curve,
        # chain 0's stream is independent of the chain count, so the max-R
        # best can only match or beat the single chain — a correctness bit
        "restarts_improve_ok": rmax["best_cost"] <= curve[0]["best_cost"],
        # R chains in one dispatch must cost far less than R sequential runs
        "restarts_wall_ok": rmax["wall_ratio_vs_r1"] < WALL_RATIO_CEILING,
    }

    # ---- device GA vs host genetic --------------------------------------
    gens, pop = (12, 16) if smoke else (80, 64)

    def host_ga():
        return optimize_placement(graph, noc, method="genetic", seed=0,
                                  generations=gens, pop_size=pop)

    def device_ga():
        return optimize_placement(graph, noc, method="genetic",
                                  backend="device", seed=0,
                                  generations=gens, pop_size=pop)

    hg, dg = host_ga(), device_ga()
    hg_lat = bench_percentiles(host_ga, repeats=repeats, warmup=0)
    dg_lat = bench_percentiles(device_ga, repeats=repeats, warmup=1)
    record["ga"] = {
        "generations": gens, "pop_size": pop,
        "host_p50_s": hg_lat["p50"], "device_p50_s": dg_lat["p50"],
        "speedup_p50": hg_lat["p50"] / max(dg_lat["p50"], 1e-12),
        "host_comm_cost": hg.comm_cost, "device_comm_cost": dg.comm_cost,
    }
    rows_out.append((
        "device_search.ga", dg_lat["p50"] * 1e6,
        f"host_p50={hg_lat['p50']*1e3:.1f}ms "
        f"device_p50={dg_lat['p50']*1e3:.1f}ms "
        f"speedup=x{record['ga']['speedup_p50']:.1f} "
        f"cost host={hg.comm_cost:.3e} dev={dg.comm_cost:.3e}"))

    # ---- recorder identity + trace --------------------------------------
    # the sa.iter/ga.gen streams are replayed post-dispatch from scan
    # outputs that are computed either way, so attaching a recorder must
    # leave the returned placements bit-identical
    recorder = Recorder()
    pa = simulated_annealing_device(graph, noc, iters=BUDGET, seed=0,
                                    restarts=4, recorder=recorder)
    pb = simulated_annealing_device(graph, noc, iters=BUDGET, seed=0,
                                    restarts=4)
    ga_a = genetic_device(graph, noc, generations=gens, pop_size=pop, seed=0,
                          recorder=recorder)
    ga_b = genetic_device(graph, noc, generations=gens, pop_size=pop, seed=0)
    identical = bool(np.array_equal(pa, pb) and np.array_equal(ga_a, ga_b))
    record["recorder_identity"] = {"results_identical": identical}
    record["counters"] = counter_record(recorder)
    rows_out.append(("device_search.recorder_identity", 0.0,
                     f"results_identical={identical} "
                     f"sa_accepted={record['counters'].get('sa_accepted', 0)}"))

    out = write_record(record, json_path, smoke, "BENCH_device_search.json")
    if out:
        rows_out.append(("device_search.json", 0.0,
                         f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "device_search", json_path, smoke)
    if tr:
        rows_out.append(("device_search.trace", 0.0,
                         f"wrote {os.path.relpath(tr)}"))
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in device_search(smoke=args.smoke,
                                           json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
