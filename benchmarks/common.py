"""Shared benchmark helpers: the paper's experimental setup in one place."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import NoC, partition_model  # noqa: E402
from repro.core.placement import optimize_placement  # noqa: E402
from repro.core.placement.ppo import PPOConfig  # noqa: E402
# timing primitives live in repro.obs now (single perf_counter implementation
# across benchmarks, the deploy engine, and the optimizer driver); re-exported
# here so every suite keeps importing them from common
from repro.obs import (bench_percentiles, bench_time,  # noqa: E402, F401
                       percentiles, timed)
from repro.snn import (profile_model, spike_resnet18, spike_resnet50,  # noqa: E402
                       spike_vgg16)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# Paper §5.1 simulator platform: many-core near-memory chip.
CORE_FLOPS = 25.6e9          # 16x16 MAC @ 100 MHz FP16 (per core)
LINK_BW = 8e9                # NoC link bytes/s
HOP_LAT = 2e-8

SPIKE_MODELS = {
    "S-ResNet18": lambda: spike_resnet18(n_classes=10, in_res=32, T=4),
    "S-VGG16": lambda: spike_vgg16(n_classes=10, in_res=32, T=4),
    "S-ResNet50": lambda: spike_resnet50(n_classes=10, in_res=32, T=4),
}


def make_noc(n_cores: int) -> NoC:
    rows = {32: 4, 64: 8}[n_cores]
    cols = n_cores // rows
    return NoC(rows, cols, torus=False, link_bw=LINK_BW,
               core_flops=CORE_FLOPS, hop_latency=HOP_LAT)


def model_graph(name: str, n_cores: int, training: bool = True, batch: int = 8):
    cfg = SPIKE_MODELS[name]()
    prof = profile_model(cfg, batch=batch, training=training)
    part = partition_model(prof, n_cores, "balanced")
    return part.to_graph(), part


def placement_suite(graph, noc, methods=("zigzag", "sigmate", "random_search",
                                         "ppo"), seed=0, ppo_iters=30,
                    ppo_batch=64, rs_budget=1500):
    rows = {}
    for m in methods:
        kw = {}
        if m == "ppo":
            kw["cfg"] = PPOConfig(batch_size=ppo_batch, iterations=ppo_iters,
                                  ppo_epochs=4, entropy_coef=3e-3, seed=seed)
        if m == "random_search":
            kw["budget"] = rs_budget
        if m == "simulated_annealing":
            kw["budget"] = 4000
        rows[m] = optimize_placement(graph, noc, method=m, seed=seed, **kw)
    return rows


def _json_default(o):
    """Numpy scalars leak into benchmark records through comparisons on
    array-backed costs (``np.float64 <= np.float64`` -> ``np.bool_``);
    stdlib json rejects them, so coerce any numpy scalar to its Python
    equivalent instead of crashing the suite at write time."""
    if isinstance(o, np.bool_):
        return bool(o)
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"Object of type {type(o).__name__} "
                    f"is not JSON serializable")


def write_record(record, json_path, smoke: bool, default_name: str):
    """Write a benchmark's JSON record under the shared output protocol:
    an explicit ``json_path`` always wins (the regression gate's fresh-smoke
    records), full runs default to ``results/<default_name>``, and smoke runs
    without an explicit path write nothing. Returns the written path or
    None."""
    out = json_path
    if out is None and not smoke:
        out = os.path.join(RESULTS_DIR, default_name)
    if out is None:
        return None
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(record, f, indent=2, default=_json_default)
    return out


def write_trace(recorder, name: str, json_path, smoke: bool):
    """Write a suite's Recorder event log as ``TRACE_<name>.jsonl`` next to
    its JSON record (same placement protocol as :func:`write_record`: explicit
    ``json_path`` pins the directory, full runs default to ``results/``, smoke
    runs without a path write nothing). Returns the written path or None."""
    if json_path is not None:
        out_dir = os.path.dirname(json_path) or "."
    elif not smoke:
        out_dir = RESULTS_DIR
    else:
        return None
    os.makedirs(out_dir, exist_ok=True)
    return recorder.write_jsonl(os.path.join(out_dir, f"TRACE_{name}.jsonl"))


def counter_record(recorder) -> dict:
    """Recorder counters with path-safe keys (``.`` -> ``_``) so the
    regression gate's dotted ``counters.<name>`` paths can address them."""
    return {k.replace(".", "_"): v for k, v in recorder.counters.items()}
