"""Benchmark: reference per-edge ``NoC.evaluate`` loop vs the batched evaluator.

Sweeps population sizes {1, 16, 64, 256} on an 8×8 mesh and a 16×16 torus
(the v5e-pod shape), timing three scorers:

* ``reference``  — sequential ``NoC.evaluate`` per placement (the seed hot path);
* ``batch_numpy``— ``noc_batch.evaluate_batch(backend="numpy")`` full metrics;
* ``batch_jax``  — same via jit+vmap (timed after a warm-up call), when jax
  is importable;

plus the comm-cost-only scorer the optimizers use, and the **fused objective
scorers**: for non-comm objectives (``max_link``, ``energy``) the jax path
historically ran the full ``evaluate`` (five metric arrays materialized on
host, combined in numpy); ``BatchedNoC.make_fused_scorer`` compiles the
objective to one device dispatch returning just the [B] scores. Sequential
simulated annealing calls the scorer at B=1 every step, so the B=1 sweep is
the before/after for SA on accelerator-backed objectives. Emits
``results/BENCH_noc_eval.json`` and the usual run.py CSV rows.

The record always carries a ``parity`` block — the max relative deviation of
the batched backends from the reference per-edge loop on seeded placements —
which is what the CI regression gate checks (timings are too noisy to gate).
``--smoke`` runs a seconds-scale subset; ``--json PATH`` writes the record
there.
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import (bench_percentiles, bench_time as _time, counter_record,
                     write_record, write_trace)
from repro.core import NoC, random_dag
from repro.core import noc_batch
from repro.obs import Recorder

POPS = (1, 16, 64, 256)
TOPOLOGIES = ((8, 8, False), (16, 16, True))


def _recorder_overhead_block(smoke: bool):
    """Observability-cost block: population SA timed detached (recorder=None,
    the production hot path) vs with a Recorder attached, plus the attached
    run's deterministic work counters and per-call latency percentiles of the
    batch scorer. The detached timing is the suite's evidence that the
    instrumentation hooks stay out of the hot loop (<2% is the budget the
    observability PR claims); the counters are seed-deterministic and gated
    by check_regression."""
    from repro.core.placement.population import simulated_annealing_population

    noc = NoC(4, 4) if smoke else NoC(8, 8)
    graph = random_dag(noc.n_cores, p=0.15, seed=0)
    iters, pop = (60, 8) if smoke else (400, 16)

    def run(recorder=None):
        return simulated_annealing_population(
            graph, noc, iters=iters, pop_size=pop, seed=0, recorder=recorder)

    run()                                     # warm the route-table cache
    repeats = 3 if smoke else 5
    off_s = _time(run, repeats=repeats)
    rec = Recorder()
    on_s = _time(lambda: run(rec), repeats=repeats)
    best_off = run()
    best_on = run(Recorder())
    # per-call latency distribution of the optimizer-facing scorer (p50/p99
    # is the serving-style summary a placement service would report)
    score = noc_batch.make_scorer(noc, graph, "batch")
    P = np.stack([np.random.default_rng(3).permutation(noc.n_cores)
                  for _ in range(pop)])
    lat = bench_percentiles(lambda: score(P), repeats=30, warmup=2)
    return {
        "iters": iters, "pop_size": pop,
        "off_s": off_s, "on_s": on_s,
        "on_overhead_frac": on_s / max(off_s, 1e-12) - 1.0,
        "results_identical": bool(np.array_equal(best_off, best_on)),
        "scorer_latency_s": lat,
    }, rec


def _parity_block():
    """Deterministic backend-parity metrics (the gate-able part)."""
    noc = NoC(4, 4, torus=True)
    graph = random_dag(noc.n_cores, p=0.15, seed=0)
    rng = np.random.default_rng(7)
    P = np.stack([rng.permutation(noc.n_cores) for _ in range(8)])
    ref = np.array([noc.evaluate(graph, p).comm_cost for p in P])
    out = {}
    score_np = noc_batch.make_scorer(noc, graph, "batch")
    out["max_rel_diff_numpy"] = float(
        np.abs(score_np(P) - ref).max() / np.abs(ref).max())
    if noc_batch.HAS_JAX:
        score_jax = noc_batch.make_scorer(noc, graph, "jax")
        out["max_rel_diff_jax"] = float(
            np.abs(np.asarray(score_jax(P), np.float64) - ref).max()
            / np.abs(ref).max())
    return out


def noc_eval(smoke: bool = False, json_path: str | None = None):
    pops = (1, 16) if smoke else POPS
    topologies = ((4, 4, False),) if smoke else TOPOLOGIES
    rows_out = []
    record = {"smoke": smoke, "populations": list(pops), "cases": [],
              "parity": _parity_block()}
    for (R, C, torus) in topologies:
        noc = NoC(R, C, torus=torus)
        n = noc.n_cores
        graph = random_dag(n, p=0.06 if n > 100 else 0.15, seed=0)
        t0 = time.perf_counter()
        bn = noc_batch.batched_noc(noc)
        build_s = time.perf_counter() - t0
        n_edges = len(graph.edges)
        rng = np.random.default_rng(1)
        case = {"rows": R, "cols": C, "torus": torus, "n_edges": n_edges,
                "table_build_s": build_s, "sweeps": []}
        for pop in pops:
            P = np.stack([rng.permutation(n) for _ in range(pop)])
            ref_s = _time(lambda: [noc.evaluate(graph, p) for p in P])
            np_s = _time(lambda: bn.evaluate(graph, P, backend="numpy"))
            score_np = noc_batch.make_scorer(noc, graph, "batch")
            cost_np_s = _time(lambda: score_np(P), repeats=3)
            sweep = {
                "pop": pop,
                "reference_s": ref_s,
                "batch_numpy_s": np_s,
                "speedup_numpy": ref_s / max(np_s, 1e-12),
                "comm_cost_numpy_s": cost_np_s,
                "speedup_comm_numpy": ref_s / max(cost_np_s, 1e-12),
            }
            if noc_batch.HAS_JAX:
                bn.evaluate(graph, P, backend="jax")     # warm-up / compile
                jax_s = _time(lambda: bn.evaluate(graph, P, backend="jax"),
                              repeats=3)
                score_jax = noc_batch.make_scorer(noc, graph, "jax")
                score_jax(P)                             # warm-up / compile
                cost_jax_s = _time(lambda: score_jax(P), repeats=3)
                sweep.update({
                    "batch_jax_s": jax_s,
                    "speedup_jax": ref_s / max(jax_s, 1e-12),
                    "comm_cost_jax_s": cost_jax_s,
                    "speedup_comm_jax": ref_s / max(cost_jax_s, 1e-12),
                })
            case["sweeps"].append(sweep)
            best = max(sweep.get("speedup_jax", 0.0), sweep["speedup_numpy"])
            rows_out.append((
                f"noc_eval.{R}x{C}{'t' if torus else ''}.pop{pop}",
                ref_s * 1e6,
                f"ref={ref_s*1e3:.1f}ms batch_np={np_s*1e3:.2f}ms "
                f"x{sweep['speedup_numpy']:.1f}"
                + (f" batch_jax={sweep['batch_jax_s']*1e3:.2f}ms "
                   f"x{sweep['speedup_jax']:.1f}" if "speedup_jax" in sweep
                   else "")
                + f" best_x{best:.1f}"))
        record["cases"].append(case)

    # ---- fused objective scorers (the sequential-SA before/after) ---------
    # Sequential SA scores B=1 per step; the fused scorer's win there is the
    # dispatch + host-materialization overhead of the full-metrics path.
    if noc_batch.HAS_JAX and not smoke:
        from repro.deploy.objective import objective_scorer
        R, C, torus = 8, 8, False
        noc = NoC(R, C, torus=torus)
        graph = random_dag(noc.n_cores, p=0.15, seed=0)
        rng = np.random.default_rng(2)
        fused_rec = {"rows": R, "cols": C, "objectives": {}}
        for objective in ("max_link", "energy"):
            obj_rec = {}
            for pop in (1, 64):
                P = np.stack([rng.permutation(noc.n_cores)
                              for _ in range(pop)])
                full = objective_scorer(noc, graph, objective, backend="jax",
                                        fused=False)
                fused = objective_scorer(noc, graph, objective, backend="jax")
                full(P)                              # warm-up / compile
                fused(P)
                full_s = _time(lambda: full(P), repeats=5)
                fused_s = _time(lambda: fused(P), repeats=5)
                obj_rec[f"pop{pop}"] = {
                    "full_metrics_s": full_s, "fused_s": fused_s,
                    "speedup": full_s / max(fused_s, 1e-12)}
                rows_out.append((
                    f"noc_eval.fused_{objective}.pop{pop}", fused_s * 1e6,
                    f"full={full_s*1e6:.0f}us fused={fused_s*1e6:.0f}us "
                    f"x{full_s / max(fused_s, 1e-12):.1f}"))
            # end-to-end: a short sequential SA under the fused jax scorer
            from repro.core.placement.baselines import simulated_annealing
            sa_s = _time(lambda: simulated_annealing(
                graph, noc, iters=200, seed=0, backend="jax",
                objective=objective))
            obj_rec["sa200_fused_s"] = sa_s
            fused_rec["objectives"][objective] = obj_rec
        record["fused_objective"] = fused_rec

    # ---- observability cost + deterministic work counters -----------------
    obs_rec, recorder = _recorder_overhead_block(smoke)
    record["recorder_overhead"] = obs_rec
    record["counters"] = counter_record(recorder)
    lat = obs_rec["scorer_latency_s"]
    rows_out.append((
        "noc_eval.recorder_overhead", obs_rec["on_s"] * 1e6,
        f"off={obs_rec['off_s']*1e3:.2f}ms on={obs_rec['on_s']*1e3:.2f}ms "
        f"overhead={obs_rec['on_overhead_frac']:+.1%} "
        f"identical={obs_rec['results_identical']}"))
    rows_out.append((
        "noc_eval.scorer_latency", lat["p50"] * 1e6,
        f"p50={lat['p50']*1e6:.1f}us p99={lat['p99']*1e6:.1f}us "
        f"n={lat['n']}"))

    p = record["parity"]
    rows_out.append(("noc_eval.parity", 0.0,
                     " ".join(f"{k}={v:.2e}" for k, v in p.items())))

    out = write_record(record, json_path, smoke, "BENCH_noc_eval.json")
    if out:
        rows_out.append(("noc_eval.json", 0.0,
                         f"wrote {os.path.relpath(out)}"))
    tr = write_trace(recorder, "noc_eval", json_path, smoke)
    if tr:
        rows_out.append(("noc_eval.trace", 0.0, f"wrote {os.path.relpath(tr)}"))
    return rows_out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the benchmark record to PATH")
    args = ap.parse_args()
    for name, us, derived in noc_eval(smoke=args.smoke, json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
